"""Sharded streaming parity (run via ``./test.sh --dist``).

The streaming executor composed with the data mesh must stay bit-identical
to single-device one-shot ``api.run`` at 1/2/4/8 virtual devices — row
state (MinHash signatures, Bloom counts) sharded with the rows, corpus
state (HLL registers, CountMin table) merged exactly once per chunk, shard
padding rows never submitting a symbol.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import CountMinSketch, MinHash
from repro.kernels import api, stream
from repro.kernels.plan import (BloomSpec, CountMinSpec, HashSpec, HLLSpec,
                                MinHashSpec, SketchPlan)

N_DEV = len(jax.devices())


def _shards(*counts):
    return [pytest.param(d, marks=pytest.mark.skipif(
        d > N_DEV, reason=f"needs {d} devices")) for d in counts]


def _h1v(shape, seed=0):
    return jax.random.bits(jax.random.PRNGKey(seed), shape, dtype=jnp.uint32)


def _plan(family):
    return SketchPlan(
        HashSpec(family=family, n=8, L=32),
        (("sig", MinHashSpec(k=16)), ("card", HLLSpec(b=4)),
         ("dec", BloomSpec(k=3, log2_m=14)),
         ("freq", CountMinSpec(depth=3, log2_width=8))))


def _operands(seed=0):
    p = MinHash(k=16).init(jax.random.PRNGKey(seed + 1))
    cp = CountMinSketch(depth=3, log2_width=8).init(
        jax.random.PRNGKey(seed + 2))
    return {"sig": {"a": p["a"], "b": p["b"]},
            "dec": {"bits": _h1v((1 << 9,), seed=seed + 3)},
            "freq": {"a": cp["a"], "b": cp["b"]}}


@pytest.mark.parametrize("d", _shards(1, 2, 4, 8))
@pytest.mark.parametrize("family", ["cyclic", "general"])
@pytest.mark.parametrize("B", [1, 5, 8])
def test_sharded_streaming_bit_identical(family, d, B):
    # B=1 and B=5 never divide d>1 (the stream state itself carries the
    # shard-padding rows); B=8 is the no-padding fast path at every d
    plan = _plan(family)
    S = 300
    x, xb = _h1v((B, S), seed=B), _h1v((B, S), seed=40 + B)
    ops = _operands()
    nw = jnp.asarray(
        np.random.default_rng(B).integers(0, S - 8 + 2, size=B), jnp.int32)
    want = api.run(plan, x, h1v_b=xb, n_windows=nw, operands=ops)
    got = stream.run_stream(plan, x, chunk_s=64, h1v_b=xb, n_windows=nw,
                            operands=ops, data_shards=d, donate=True)
    for name in want:
        np.testing.assert_array_equal(np.asarray(got[name]),
                                      np.asarray(want[name]), err_msg=name)


@pytest.mark.parametrize("d", _shards(2))
def test_sharded_streaming_pallas_interpret(d):
    plan = _plan("cyclic")
    x, xb = _h1v((5, 280)), _h1v((5, 280), seed=9)
    ops = _operands()
    want = api.run(plan, x, h1v_b=xb, operands=ops, impl="pallas",
                   block_b=2, block_s=256)
    got = stream.run_stream(plan, x, chunk_s=70, h1v_b=xb, operands=ops,
                            impl="pallas", block_b=2, block_s=256,
                            data_shards=d)
    for name in want:
        np.testing.assert_array_equal(np.asarray(got[name]),
                                      np.asarray(want[name]), err_msg=name)


@pytest.mark.parametrize("d", _shards(4))
def test_sharded_dedup_streaming_flags(d):
    from repro.data.dedup import DedupConfig, MinHashDeduper
    rng = np.random.default_rng(0)
    docs = [rng.integers(0, 4096, size=int(n)).astype(np.int32)
            for n in rng.integers(20, 500, size=20)]
    docs.append(docs[2].copy())
    with MinHashDeduper(DedupConfig(vocab=4096)) as base, \
         MinHashDeduper(DedupConfig(vocab=4096, data_shards=d,
                                    stream_rows=8,
                                    stream_chunk_s=128)) as sharded:
        np.testing.assert_array_equal(base.add_batch(docs),
                                      sharded.add_batch(docs))


@pytest.mark.parametrize("d", _shards(2))
def test_sharded_stats_stream(d):
    from repro.data.stats import NgramStats, StatsConfig
    rng = np.random.default_rng(1)
    toks = rng.integers(0, 4096, size=(3, 256)).astype(np.uint32)
    base = NgramStats(StatsConfig(vocab=4096))
    want = base.update(base.init_state(), toks)
    st = NgramStats(StatsConfig(vocab=4096, data_shards=d))
    ss = st.init_stream(3)
    for c in range(0, 256, 64):
        ss = st.update_stream(ss, toks[:, c : c + 64])
    got = st.finalize_stream(ss)
    for k in ("hll", "cms", "tokens"):
        np.testing.assert_array_equal(np.asarray(got[k]),
                                      np.asarray(want[k]))
