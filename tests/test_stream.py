# lint: allow-deprecated-shims — this suite certifies the streaming executor
# against the demoted bucketed oracle (_signature_many_bucketed)
"""Chunked streaming executor (kernels/stream.py) — PR 5 acceptance.

All bit-exact:
* ``run_stream`` == one-shot ``api.run`` for all four sketches x {cyclic,
  general} x chunk sizes {n, n+1, 64, 1024} x ragged tails, on both
  executors (jnp ref and Pallas interpret);
* chunk boundaries hash boundary-spanning windows exactly once (the carry
  tail + w_start leading mask), documents shorter than one chunk and
  shorter than the window included;
* compile-count regressions: a mixed-length corpus signs through exactly
  ONE streaming trace (the bucketed baseline needed one per shape bucket)
  and the donated-carry loop never retraces across chunks;
* the consumers' streaming paths (dedup signing, stats, decontam) equal
  their whole-batch counterparts.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import CountMinSketch, MinHash
from repro.kernels import api, stream
from repro.kernels.plan import (BloomSpec, CountMinSpec, HashSpec, HLLSpec,
                                MinHashSpec, SketchPlan)


def _h1v(shape, seed=0):
    return jax.random.bits(jax.random.PRNGKey(seed), shape, dtype=jnp.uint32)


def _plan(family, n=8):
    return SketchPlan(
        HashSpec(family=family, n=n, L=32),
        (("sig", MinHashSpec(k=16)), ("card", HLLSpec(b=4)),
         ("dec", BloomSpec(k=3, log2_m=14)),
         ("freq", CountMinSpec(depth=3, log2_width=8))))


def _operands(seed=0):
    p = MinHash(k=16).init(jax.random.PRNGKey(seed + 1))
    cp = CountMinSketch(depth=3, log2_width=8).init(jax.random.PRNGKey(seed + 2))
    return {"sig": {"a": p["a"], "b": p["b"]},
            "dec": {"bits": _h1v((1 << 9,), seed=seed + 3)},
            "freq": {"a": cp["a"], "b": cp["b"]}}


def _assert_same(got, want):
    for name in want:
        np.testing.assert_array_equal(np.asarray(got[name]),
                                      np.asarray(want[name]),
                                      err_msg=name)


IMPLS = [("ref", {}), ("pallas", dict(block_b=2, block_s=256))]


# ---------------------------------------------------------------------------
# bit-identity vs one-shot api.run
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("family", ["cyclic", "general"])
@pytest.mark.parametrize("n", [2, 8])
@pytest.mark.parametrize("chunk_kind", ["n", "n+1", "64", "1024"])
def test_run_stream_bit_identical(family, n, chunk_kind):
    B, S = 4, 300
    plan = _plan(family, n)
    x, xb = _h1v((B, S), seed=n), _h1v((B, S), seed=50 + n)
    ops = _operands()
    # ragged: per-row window counts from 0 (fully masked) to full
    nw = jnp.asarray([0, 1, S // 2, S - n + 1], jnp.int32)
    chunk_s = {"n": n, "n+1": n + 1, "64": 64, "1024": 1024}[chunk_kind]
    want = api.run(plan, x, h1v_b=xb, n_windows=nw, operands=ops)
    got = stream.run_stream(plan, x, chunk_s=chunk_s, h1v_b=xb,
                            n_windows=nw, operands=ops, donate=True)
    _assert_same(got, want)


@pytest.mark.parametrize("impl,tile", IMPLS)
def test_run_stream_both_executors(impl, tile):
    B, S = 3, 290
    plan = _plan("cyclic")
    x, xb = _h1v((B, S)), _h1v((B, S), seed=7)
    ops = _operands()
    want = api.run(plan, x, h1v_b=xb, operands=ops, impl=impl, **tile)
    got = stream.run_stream(plan, x, chunk_s=63, h1v_b=xb, operands=ops,
                            impl=impl, **tile)
    _assert_same(got, want)


def test_run_stream_short_documents():
    # rows shorter than one chunk AND shorter than the window: identities
    plan = _plan("cyclic", 8)
    B, S = 3, 5                      # S < n
    x, xb = _h1v((B, S)), _h1v((B, S), seed=3)
    ops = _operands()
    got = stream.run_stream(plan, x, chunk_s=64, h1v_b=xb, operands=ops)
    assert (np.asarray(got["sig"]) == 0xFFFFFFFF).all()
    assert (np.asarray(got["card"]) == 0).all()
    assert (np.asarray(got["dec"]) == 0).all()
    assert (np.asarray(got["freq"]) == 0).all()


def test_run_stream_leading_dims():
    plan = SketchPlan(HashSpec(family="cyclic", n=8),
                      (("sig", MinHashSpec(k=16)),))
    p = MinHash(k=16).init(jax.random.PRNGKey(1))
    x = _h1v((2, 3, 200))
    ops = {"sig": {"a": p["a"], "b": p["b"]}}
    want = api.run(plan, x, operands=ops)
    got = stream.run_stream(plan, x, chunk_s=64, operands=ops)
    assert got["sig"].shape == (2, 3, 16)
    np.testing.assert_array_equal(np.asarray(got["sig"]),
                                  np.asarray(want["sig"]))


def test_cms_scatter_mode_streams_too():
    # log2_width above the in-kernel threshold: the carry folds in the XLA
    # scatter epilogue instead of VMEM scratch — still bit-exact
    plan = SketchPlan(
        HashSpec(family="cyclic", n=8),
        (("freq", CountMinSpec(depth=2, log2_width=10,
                               in_kernel_max_log2_width=8)),))
    cp = CountMinSketch(depth=2, log2_width=10).init(jax.random.PRNGKey(5))
    ops = {"freq": {"a": cp["a"], "b": cp["b"]}}
    x = _h1v((3, 200))
    for impl, tile in IMPLS:
        want = api.run(plan, x, operands=ops, impl=impl, **tile)
        got = stream.run_stream(plan, x, chunk_s=37, operands=ops,
                                impl=impl, **tile)
        _assert_same(got, want)


# ---------------------------------------------------------------------------
# the stateful API: unbounded streams, independent rows, resume
# ---------------------------------------------------------------------------


def test_stateful_rows_advance_independently():
    # rows pause and resume (ragged lengths per chunk); the result equals
    # one-shot hashing of each row's concatenated stream
    plan = SketchPlan(HashSpec(family="cyclic", n=8),
                      (("sig", MinHashSpec(k=16)), ("card", HLLSpec(b=4))))
    p = MinHash(k=16).init(jax.random.PRNGKey(1))
    ops = {"sig": {"a": p["a"], "b": p["b"]}}
    rng = np.random.default_rng(0)
    B, C = 3, 16
    feeds = [[], [], []]             # per-row concatenated symbols
    state = stream.init_state(plan, B)
    for step in range(12):
        lengths = rng.integers(0, C + 1, size=B)      # idle rows included
        chunk = rng.integers(0, 2**32, size=(B, C), dtype=np.uint32)
        for r in range(B):
            feeds[r].extend(chunk[r, : lengths[r]].tolist())
        state = stream.update(plan, state, jnp.asarray(chunk),
                              lengths=lengths, operands=ops)
    got = stream.finalize(plan, state)
    L = max(len(f) for f in feeds)
    x = np.zeros((B, max(L, 8)), np.uint32)
    nw = np.zeros((B,), np.int32)
    for r, f in enumerate(feeds):
        x[r, : len(f)] = f
        nw[r] = max(0, len(f) - 8 + 1)
    want = api.run(plan, jnp.asarray(x), n_windows=jnp.asarray(nw),
                   operands=ops)
    _assert_same(got, want)


def test_update_validation():
    plan = SketchPlan(HashSpec(family="cyclic", n=8),
                      (("sig", MinHashSpec(k=16)),))
    p = MinHash(k=16).init(jax.random.PRNGKey(1))
    ops = {"sig": {"a": p["a"], "b": p["b"]}}
    state = stream.init_state(plan, 2)
    with pytest.raises(ValueError, match="do not pass 'init'"):
        stream.update(plan, state, _h1v((2, 16)),
                      operands={"sig": {**ops["sig"],
                                        "init": state["sketch"]["sig"]}})
    with pytest.raises(ValueError, match="lengths shape"):
        stream.update(plan, state, _h1v((2, 16)), lengths=jnp.zeros((3,)),
                      operands=ops)
    with pytest.raises(ValueError, match="lengths must be non-negative"
                                         ".*row 1 has -5"):
        # a negative length would silently rewind `seen` and corrupt the
        # carried tail for every subsequent chunk of that stream
        stream.update(plan, state, _h1v((2, 16)),
                      lengths=jnp.asarray([3, -5]), operands=ops)
    with pytest.raises(ValueError, match="lengths must be <= 16"
                                         ".*row 0 has 50"):
        # oversize would be clipped by the engine while the caller's own
        # symbol accounting keeps the raw value — silent desync
        stream.update(plan, state, _h1v((2, 16)),
                      lengths=jnp.asarray([50, 3]), operands=ops)
    with pytest.raises(ValueError, match="chunk rows 4 > stream state"):
        stream.update(plan, state, _h1v((4, 16)), operands=ops)
    with pytest.raises(ValueError, match="second stream"):
        bplan = SketchPlan(HashSpec(family="cyclic", n=8),
                           (("dec", BloomSpec(k=2, log2_m=14)),))
        stream.update(bplan, stream.init_state(bplan, 2), _h1v((2, 16)),
                      operands={"dec": {"bits": _h1v((1 << 9,))}})
    with pytest.raises(ValueError, match="carry for sketches not in plan"):
        stream.init_state(plan, 2, carry={"ghost": jnp.zeros((2, 16))})


# ---------------------------------------------------------------------------
# compile-count regressions
# ---------------------------------------------------------------------------


def _stream_traces():
    # every executor twin the streaming module dispatches through: the
    # per-chunk update pair (host loop) and the scan pair (on-device loop)
    return (stream._update_plain._cache_size()
            + stream._update_donated._cache_size()
            + stream._scan_plain._cache_size()
            + stream._scan_donated._cache_size())


def test_mixed_length_corpus_signs_with_bounded_traces():
    # the headline compile-count property: log-uniform lengths populate
    # many power-of-two buckets (the old path compiled one executor per
    # bucket, unbounded as lengths grow); the scan executor sees at most
    # log2(stream_block_chunks)+1 distinct block shapes EVER — full blocks
    # plus pow2 tail blocks — independent of the corpus length mix
    from repro.data.dedup import DedupConfig, MinHashDeduper
    rng = np.random.default_rng(0)
    docs = [rng.integers(0, 4096, size=int(n)).astype(np.int32)
            for n in np.exp(rng.uniform(np.log(4), np.log(3000), size=30))]
    cfg = DedupConfig(vocab=4096, stream_rows=8, stream_chunk_s=128)
    bound = int(np.log2(cfg.stream_block_chunks)) + 1
    with MinHashDeduper(cfg) as dd:
        before = _stream_traces()
        d0 = stream.dispatch_count()
        sigs = dd.signature_many(docs)
        assert _stream_traces() - before <= bound
        # ... and at a fraction of the host loop's dispatch count: one per
        # block of chunks, not one per chunk
        n_groups = -(-len(docs) // 8)
        assert stream.dispatch_count() - d0 <= n_groups * 4
        # a second corpus with a very different length mix stays inside the
        # same constant trace budget (block shapes, not length buckets)
        docs2 = [rng.integers(0, 4096, size=int(n)).astype(np.int32)
                 for n in rng.integers(1, 2500, size=40)]
        sigs2 = dd.signature_many(docs2)
        assert _stream_traces() - before <= bound
        # and the bucketed oracle really did need one trace per bucket
        b0 = dd._sig_fn._cache_size()
        want = dd._signature_many_bucketed(docs)
        assert dd._sig_fn._cache_size() - b0 > 1
        np.testing.assert_array_equal(sigs, want)        # bit-exact too
        np.testing.assert_array_equal(sigs2,
                                      dd._signature_many_bucketed(docs2))


def test_donated_carry_loop_does_not_retrace():
    plan = SketchPlan(HashSpec(family="cyclic", n=8),
                      (("sig", MinHashSpec(k=16)),))
    p = MinHash(k=16).init(jax.random.PRNGKey(1))
    ops = {"sig": {"a": p["a"], "b": p["b"]}}
    state = stream.init_state(plan, 4)
    chunk = _h1v((4, 32))
    state = stream.update(plan, state, chunk, operands=ops, donate=True)
    before = stream._update_donated._cache_size()
    for _ in range(5):
        state = stream.update(plan, state, chunk, operands=ops, donate=True)
    assert stream._update_donated._cache_size() == before
    # a donated steady-state loop still computes the right thing
    want = api.run(plan, jnp.tile(chunk, (1, 6)).reshape(4, -1),
                   operands=ops)
    np.testing.assert_array_equal(
        np.asarray(stream.finalize(plan, state)["sig"]),
        np.asarray(want["sig"]))


# ---------------------------------------------------------------------------
# consumers: streaming == whole-batch
# ---------------------------------------------------------------------------


def test_dedup_streaming_signatures_and_flags():
    from repro.data.dedup import DedupConfig, MinHashDeduper
    rng = np.random.default_rng(1)
    lens = list(rng.integers(3, 700, size=24)) + [1, 2000]
    docs = [rng.integers(0, 4096, size=int(n)).astype(np.int32)
            for n in lens]
    docs.append(docs[3].copy())                      # exact duplicate
    with MinHashDeduper(DedupConfig(vocab=4096, stream_rows=8,
                                    stream_chunk_s=64)) as dd:
        sigs = dd.signature_many(docs)
        np.testing.assert_array_equal(sigs, dd._signature_many_bucketed(docs))
        for i in (0, 5, 23):
            if len(docs[i]) >= 8:
                np.testing.assert_array_equal(sigs[i],
                                              dd.signature_unfused(docs[i]))
        flags = dd.add_batch(docs)
    assert flags[-1]                                  # duplicate caught
    with MinHashDeduper(DedupConfig(vocab=4096)) as dd2:   # other tiling
        np.testing.assert_array_equal(flags, dd2.add_batch(docs))


def test_dedup_unfused_family_short_docs_survive_bucket_floor_removal():
    # regression: removing _bucket's min-64 floor must not crash the
    # bucketed fallback (non-fused families) on docs shorter than the
    # window — they pad up to one physical window and sign to sentinel
    from repro.data.dedup import DedupConfig, MinHashDeduper
    with MinHashDeduper(DedupConfig(vocab=256, family="threewise",
                                    ngram_n=8)) as dd:
        assert dd.plan is None
        docs = [np.arange(3, dtype=np.int32),
                np.arange(40, dtype=np.int32) % 256]
        sigs = dd.signature_many(docs)
    assert (sigs[0] == 0xFFFFFFFF).all()
    assert not (sigs[1] == 0xFFFFFFFF).all()


def test_dedup_general_family_streams_too():
    from repro.data.dedup import DedupConfig, MinHashDeduper
    rng = np.random.default_rng(2)
    docs = [rng.integers(0, 4096, size=int(n)).astype(np.int32)
            for n in rng.integers(40, 400, size=10)]
    with MinHashDeduper(DedupConfig(vocab=4096, family="general",
                                    stream_rows=4,
                                    stream_chunk_s=96)) as dd:
        np.testing.assert_array_equal(dd.signature_many(docs),
                                      dd._signature_many_bucketed(docs))


def test_stats_streaming_equals_whole_batch():
    from repro.data.stats import NgramStats, StatsConfig
    st = NgramStats(StatsConfig(vocab=4096))
    rng = np.random.default_rng(3)
    toks = rng.integers(0, 4096, size=(4, 384)).astype(np.uint32)
    want = st.update(st.init_state(), toks)
    ss = st.init_stream(4)
    for c in range(0, 384, 48):
        ss = st.update_stream(ss, toks[:, c : c + 48])
    got = st.finalize_stream(ss)
    for k in ("hll", "cms", "tokens"):
        np.testing.assert_array_equal(np.asarray(got[k]),
                                      np.asarray(want[k]))
    # a second stream continues from the finalized state exactly
    toks2 = rng.integers(0, 4096, size=(4, 128)).astype(np.uint32)
    want2 = st.update(want, toks2)
    ss2 = st.init_stream(4, state=got)
    ss2 = st.update_stream(ss2, toks2)
    got2 = st.finalize_stream(ss2)
    for k in ("hll", "cms", "tokens"):
        np.testing.assert_array_equal(np.asarray(got2[k]),
                                      np.asarray(want2[k]))


def test_stats_streaming_needs_fused_family():
    from repro.data.stats import NgramStats, StatsConfig
    st = NgramStats(StatsConfig(vocab=256, family="threewise"))
    with pytest.raises(ValueError, match="fused family"):
        st.init_stream(2)


def test_decontam_streaming_equals_whole_batch():
    from repro.data.decontam import DecontamConfig, Decontaminator
    # 64 planted symbols of 256 -> ~0.23 of windows hit; flag above 0.15
    dc = Decontaminator(DecontamConfig(log2_m=14, vocab=4096,
                                       max_hit_frac=0.15))
    rng = np.random.default_rng(4)
    ev = rng.integers(0, 4096, size=(4, 64)).astype(np.uint32)
    dc.add_eval_set(ev)
    batch = rng.integers(0, 4096, size=(5, 256)).astype(np.uint32)
    batch[0, :64] = ev[0]                            # planted contamination
    want = np.asarray(dc.contamination(batch))
    ss = dc.init_stream(5)
    for c in range(0, 256, 32):
        ss = dc.update_stream(ss, batch[:, c : c + 32])
    got = dc.finalize_stream(ss)
    np.testing.assert_allclose(got, want, rtol=1e-6)
    assert got[0] > dc.cfg.max_hit_frac              # still flagged
