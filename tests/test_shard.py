"""Multi-device parity for the sharded plan engine (kernels/shard.py).

Acceptance, all bit-exact:
* ``run_sharded`` == ``api.run`` on 1/2/4/8 virtual devices — including
  ragged batch sizes not divisible by the shard count (and batches smaller
  than it), for both hash families and all three sketches, on the jnp and
  Pallas-interpret executors;
* the HLL register combine lowers to exactly ONE cross-device max
  (``pmax``) and the row-parallel sketches add no collective at all;
* the dedup/stats/decontam services produce identical state with their
  ``data_shards`` knob on;
* mesh/shard-count validation raises early and clearly.

Run via ``./test.sh --dist`` (8 virtual CPU devices); shard counts beyond
the available device count skip rather than fail so the suite also passes
on a bare single-device interpreter.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import CountMinSketch, MinHash
from repro.kernels import api, shard
from repro.kernels.plan import (BloomSpec, CountMinSpec, HashSpec, HLLSpec,
                                MinHashSpec, SketchPlan)
from repro.analysis.jaxpr import (assert_counts, assert_no_collectives,
                                  count_primitive as _count_primitive)

N_DEV = len(jax.devices())


def _shards(*counts):
    return [pytest.param(d, marks=pytest.mark.skipif(
        d > N_DEV, reason=f"needs {d} devices")) for d in counts]


def _h1v(shape, seed=0):
    return jax.random.bits(jax.random.PRNGKey(seed), shape, dtype=jnp.uint32)


def _plan(family, n=8):
    return SketchPlan(
        HashSpec(family=family, n=n, L=32),
        (("sig", MinHashSpec(k=32)), ("card", HLLSpec(b=4)),
         ("dec", BloomSpec(k=3, log2_m=14)),
         ("freq", CountMinSpec(depth=3, log2_width=8))))


def _inputs(B, S=300, seed=0):
    p = MinHash(k=32).init(jax.random.PRNGKey(seed + 1))
    cp = CountMinSketch(depth=3, log2_width=8).init(
        jax.random.PRNGKey(seed + 2))
    return dict(
        x=_h1v((B, S), seed=seed), xb=_h1v((B, S), seed=seed + 50),
        nw=jnp.asarray(
            np.random.default_rng(seed).integers(1, S - 8 + 2, size=B),
            jnp.int32),
        operands={"sig": {"a": p["a"], "b": p["b"]},
                  "dec": {"bits": _h1v((1 << 9,), seed=seed + 99)},
                  "freq": {"a": cp["a"], "b": cp["b"]}})


def _assert_same(got, want):
    for name in ("sig", "card", "dec", "freq"):
        np.testing.assert_array_equal(np.asarray(got[name]),
                                      np.asarray(want[name]))


# ---------------------------------------------------------------------------
# bit-identity vs api.run: ragged batches, every family, every sketch
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("d", _shards(1, 2, 4, 8))
@pytest.mark.parametrize("family", ["cyclic", "general"])
@pytest.mark.parametrize("B", [1, 5, 8])
def test_run_sharded_bit_identical(family, d, B):
    # B=1 and B=5 never divide d>1 (heavy padding, incl. whole empty
    # shards); B=8 hits the no-padding fast path at every d
    plan = _plan(family)
    a = _inputs(B, seed=7 * B)
    want = api.run(plan, a["x"], h1v_b=a["xb"], n_windows=a["nw"],
                   operands=a["operands"])
    got = shard.run_sharded(plan, a["x"], h1v_b=a["xb"], n_windows=a["nw"],
                            operands=a["operands"], data_shards=d)
    _assert_same(got, want)


@pytest.mark.parametrize("d", _shards(2))
def test_run_sharded_pallas_interpret(d):
    plan = _plan("cyclic")
    a = _inputs(5)
    want = api.run(plan, a["x"], h1v_b=a["xb"], n_windows=a["nw"],
                   operands=a["operands"], impl="pallas",
                   block_b=2, block_s=256)
    got = shard.run_sharded(plan, a["x"], h1v_b=a["xb"], n_windows=a["nw"],
                            operands=a["operands"], impl="pallas",
                            block_b=2, block_s=256, data_shards=d)
    _assert_same(got, want)


@pytest.mark.parametrize("d", _shards(1, 4))
def test_run_sharded_leading_dims_and_default_windows(d):
    # (2, 3, S) leading dims, n_windows=None: same restore rules as api.run
    plan = SketchPlan(HashSpec(family="cyclic", n=8),
                      (("sig", MinHashSpec(k=16)),))
    p = MinHash(k=16).init(jax.random.PRNGKey(3))
    x = _h1v((2, 3, 200), seed=4)
    ops = {"sig": {"a": p["a"], "b": p["b"]}}
    want = api.run(plan, x, operands=ops)
    got = shard.run_sharded(plan, x, operands=ops, data_shards=d)
    assert got["sig"].shape == (2, 3, 16)
    np.testing.assert_array_equal(np.asarray(got["sig"]),
                                  np.asarray(want["sig"]))


def test_run_sharded_explicit_mesh():
    mesh = shard.data_mesh(min(2, N_DEV))
    plan = _plan("cyclic")
    a = _inputs(5)
    got = shard.run_sharded(plan, a["x"], h1v_b=a["xb"], n_windows=a["nw"],
                            operands=a["operands"], mesh=mesh)
    want = api.run(plan, a["x"], h1v_b=a["xb"], n_windows=a["nw"],
                   operands=a["operands"])
    _assert_same(got, want)


# ---------------------------------------------------------------------------
# the combine epilogues: one pmax for HLL, none for row-parallel sketches
# ---------------------------------------------------------------------------


def test_hll_combine_is_single_pmax():
    d = min(2, N_DEV)
    plan = SketchPlan(HashSpec(family="cyclic", n=8),
                      (("card", HLLSpec(b=4)),))

    def fn(x):
        return shard.run_sharded(plan, x, data_shards=d)["card"]

    jaxpr = jax.make_jaxpr(fn)(_h1v((4, 128)))
    assert_counts(jaxpr, pmax=1, psum=0)


def test_row_parallel_sketches_need_no_collective():
    d = min(2, N_DEV)
    plan = SketchPlan(HashSpec(family="cyclic", n=8),
                      (("sig", MinHashSpec(k=8)),
                       ("dec", BloomSpec(k=3, log2_m=14))))
    p = MinHash(k=8).init(jax.random.PRNGKey(0))
    ops = {"sig": {"a": p["a"], "b": p["b"]},
           "dec": {"bits": _h1v((1 << 9,))}}

    def fn(x, xb):
        return shard.run_sharded(plan, x, h1v_b=xb, operands=ops,
                                 data_shards=d)

    jaxpr = jax.make_jaxpr(fn)(_h1v((4, 128)), _h1v((4, 128), 1))
    assert_no_collectives(jaxpr)


def test_data_mesh_is_cached_per_devices_and_count():
    d = min(2, N_DEV)
    # mesh is a static arg of the jit'd _run_sharded: the factory must
    # return one object per (device-tuple, d) regardless of whether the
    # running JAX version interns Mesh by value
    assert shard.data_mesh(d) is shard.data_mesh(d)
    assert shard.data_mesh() is shard.data_mesh(N_DEV)


def test_run_sharded_traces_once_across_repeated_calls():
    # the per-batch service pattern: run_auto(..., data_shards=...) every
    # step — same plan, same shapes — must compile the sharded executor
    # exactly once, not once per step
    d = min(2, N_DEV)
    plan = SketchPlan(HashSpec(family="cyclic", n=8),
                      (("card", HLLSpec(b=4)),
                       ("freq", CountMinSpec(depth=3, log2_width=8))))
    cp = CountMinSketch(depth=3, log2_width=8).init(jax.random.PRNGKey(0))
    ops = {"freq": {"a": cp["a"], "b": cp["b"]}}
    before = shard._run_sharded._cache_size()
    for step in range(4):
        shard.run_auto(plan, _h1v((6, 128), seed=step), operands=ops,
                       data_shards=d)
    assert shard._run_sharded._cache_size() - before == 1


# ---------------------------------------------------------------------------
# services: the data_shards knob changes nothing but the device count
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("d", _shards(4))
def test_dedup_sharded_matches_single_device(d):
    from repro.data.dedup import DedupConfig, MinHashDeduper
    rng = np.random.default_rng(0)
    docs = [rng.integers(0, 4096, size=int(s)).astype(np.int32)
            for s in rng.integers(40, 300, size=30)]
    base = MinHashDeduper(DedupConfig(vocab=4096, threshold=0.5))
    sharded = MinHashDeduper(DedupConfig(vocab=4096, threshold=0.5,
                                         data_shards=d, lsh_workers=4))
    np.testing.assert_array_equal(base.add_batch(docs),
                                  sharded.add_batch(docs))
    assert base._bands == sharded._bands       # identical index state
    for x, y in zip(base._sigs, sharded._sigs):
        np.testing.assert_array_equal(x, y)


@pytest.mark.parametrize("d", _shards(8))
def test_stats_sharded_matches_single_device(d):
    from repro.data.stats import NgramStats, StatsConfig
    toks = np.random.default_rng(1).integers(
        0, 1000, size=(16, 256)).astype(np.uint32)
    s0 = NgramStats(StatsConfig())
    s1 = NgramStats(StatsConfig(data_shards=d))
    st0 = s0.update(s0.init_state(), toks)
    st1 = s1.update(s1.init_state(), toks)
    for leg in ("hll", "cms"):
        np.testing.assert_array_equal(np.asarray(st0[leg]),
                                      np.asarray(st1[leg]))


@pytest.mark.parametrize("d", _shards(8))
def test_decontam_sharded_matches_single_device(d):
    from repro.data.decontam import DecontamConfig, Decontaminator
    rng = np.random.default_rng(2)
    d0 = Decontaminator(DecontamConfig(log2_m=14))
    d1 = Decontaminator(DecontamConfig(log2_m=14, data_shards=d))
    ev = rng.integers(0, 1000, size=(4, 64)).astype(np.uint32)
    d0.add_eval_set(ev)
    d1.add_eval_set(ev)
    batch = rng.integers(0, 1000, size=(5, 128)).astype(np.uint32)
    np.testing.assert_array_equal(d0.contamination(batch),
                                  d1.contamination(batch))


# ---------------------------------------------------------------------------
# validation
# ---------------------------------------------------------------------------


def test_mesh_validation():
    with pytest.raises(ValueError, match="data_shards"):
        shard.data_mesh(N_DEV + 1)
    with pytest.raises(ValueError, match="data_shards"):
        shard.data_mesh(0)
    plan = SketchPlan(HashSpec(n=8), (("sig", MinHashSpec(k=8)),))
    p = MinHash(k=8).init(jax.random.PRNGKey(0))
    ops = {"sig": {"a": p["a"], "b": p["b"]}}
    if N_DEV >= 2:
        from jax.sharding import Mesh
        twod = Mesh(np.array(jax.devices()[:2]).reshape(2, 1), ("a", "b"))
        with pytest.raises(ValueError, match="1-D data mesh"):
            shard.run_sharded(plan, _h1v((2, 64)), operands=ops, mesh=twod)
    # the shared validation front end behaves exactly like api.run: short
    # rows are legal fully-masked batches (n_windows = 0), bad operands
    # raise the same error
    short = shard.run_sharded(plan, _h1v((2, 4)), operands=ops,
                              data_shards=1)
    assert (np.asarray(short["sig"]) == 0xFFFFFFFF).all()
    with pytest.raises(ValueError, match="needs operands"):
        shard.run_sharded(plan, _h1v((2, 64)), data_shards=1)
