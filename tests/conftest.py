"""Expose 8 virtual CPU devices before jax initializes, so the multi-device
parity tests (tests/test_shard.py, tests/test_distributed.py) exercise a
real partitioning even under a bare ``pytest`` invocation. ``test.sh``
exports the same flag; an operator-provided XLA_FLAGS that already pins a
device count wins."""
import os
import sys

if "jax" not in sys.modules:
    flags = os.environ.get("XLA_FLAGS", "")
    if "xla_force_host_platform_device_count" not in flags:
        os.environ["XLA_FLAGS"] = (
            "--xla_force_host_platform_device_count=8 " + flags).strip()
